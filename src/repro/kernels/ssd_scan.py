"""Mamba2 SSD chunked scan for TPU.

Grid = (batch, head, chunk) with the chunk axis innermost: the running
inter-chunk state (P x N, f32) lives in VMEM scratch and is carried
across sequential grid steps — the TPU-native replacement for the GPU
kernel's warp-level chunk pipeline. Per chunk the intra-chunk quadratic
term is two (Q,N)x(N,Q) / (Q,Q)x(Q,P) MXU matmuls; Q=128 keeps every
matmul dim hardware-aligned.

Layouts (head-major so one program owns one head's sequence):
  x   (B, H, nc, Q, P)   dtA (B, H, nc, Q)   dt (B, H, nc, Q)
  B_  (B, H, nc, Q, N)   C_  (B, H, nc, Q, N)
Outputs: y (B, H, nc, Q, P), final state (B, H, P, N) f32.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

F32 = jnp.float32


def _ssd_kernel(
    x_ref,  # (1, 1, 1, Q, P)
    dta_ref,  # (1, 1, 1, Q)
    dt_ref,  # (1, 1, 1, Q)
    b_ref,  # (1, 1, 1, Q, N)
    c_ref,  # (1, 1, 1, Q, N)
    y_ref,  # (1, 1, 1, Q, P)
    fs_ref,  # (1, 1, P, N) final state
    state,  # scratch (P, N) f32
    *,
    num_chunks: int,
):
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _init():
        state[...] = jnp.zeros_like(state)

    x = x_ref[0, 0, 0].astype(F32)  # (Q, P)
    dta = dta_ref[0, 0, 0].astype(F32)  # (Q,)
    dt = dt_ref[0, 0, 0].astype(F32)  # (Q,)
    B_ = b_ref[0, 0, 0].astype(F32)  # (Q, N)
    C_ = c_ref[0, 0, 0].astype(F32)  # (Q, N)

    cs = jnp.cumsum(dta)  # (Q,) inclusive
    # intra-chunk: scores[q,k] = C_q . B_k, decay L[q,k] = exp(cs_q - cs_k)
    scores = jax.lax.dot_general(
        C_, B_, (((1,), (1,)), ((), ())), preferred_element_type=F32
    )  # (Q, Q)
    diff = cs[:, None] - cs[None, :]
    Q = cs.shape[0]
    tri = (
        jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0)
        >= jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1)
    )
    L = jnp.where(tri, jnp.exp(diff), 0.0)
    M = scores * L * dt[None, :]
    y = jax.lax.dot_general(
        M, x, (((1,), (0,)), ((), ())), preferred_element_type=F32
    )  # (Q, P)
    # inter-chunk: y += (C * exp(cs)) @ state^T
    Cw = C_ * jnp.exp(cs)[:, None]
    y += jax.lax.dot_general(
        Cw, state[...], (((1,), (1,)), ((), ())), preferred_element_type=F32
    )
    y_ref[0, 0, 0] = y.astype(y_ref.dtype)

    # state update: state = exp(cs_last) * state + x^T @ (B * w)
    w = jnp.exp(cs[-1] - cs) * dt  # (Q,)
    upd = jax.lax.dot_general(
        x, B_ * w[:, None], (((0,), (0,)), ((), ())), preferred_element_type=F32
    )  # (P, N)
    state[...] = jnp.exp(cs[-1]) * state[...] + upd

    @pl.when(ic == num_chunks - 1)
    def _final():
        fs_ref[0, 0] = state[...]


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(
    x: jax.Array,  # (B, S, H, P)
    dt: jax.Array,  # (B, S, H) softplus'ed
    A: jax.Array,  # (H,) negative
    B_: jax.Array,  # (B, S, H, N)
    C_: jax.Array,  # (B, S, H, N)
    *,
    chunk: int = 128,
    interpret: bool = False,
):
    """Returns (y (B,S,H,P), final_state (B,H,P,N))."""
    B, S, H, P = x.shape
    N = B_.shape[-1]
    assert S % chunk == 0, (S, chunk)
    nc, Q = S // chunk, chunk

    def head_major(t):  # (B,S,H,...) -> (B,H,nc,Q,...)
        t = jnp.moveaxis(t, 2, 1)  # (B,H,S,...)
        return t.reshape(t.shape[:2] + (nc, Q) + t.shape[3:])

    xr = head_major(x)
    dtr = head_major(dt[..., None])[..., 0]  # (B,H,nc,Q)
    dta = dtr * A[None, :, None, None].astype(F32)
    Br = head_major(B_)
    Cr = head_major(C_)

    kernel = functools.partial(_ssd_kernel, num_chunks=nc)
    y, fs = pl.pallas_call(
        kernel,
        grid=(B, H, nc),
        in_specs=[
            pl.BlockSpec((1, 1, 1, Q, P), lambda b, h, c: (b, h, c, 0, 0)),
            pl.BlockSpec((1, 1, 1, Q), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, 1, Q), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, 1, Q, N), lambda b, h, c: (b, h, c, 0, 0)),
            pl.BlockSpec((1, 1, 1, Q, N), lambda b, h, c: (b, h, c, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, 1, Q, P), lambda b, h, c: (b, h, c, 0, 0)),
            pl.BlockSpec((1, 1, P, N), lambda b, h, c: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, H, nc, Q, P), x.dtype),
            jax.ShapeDtypeStruct((B, H, P, N), F32),
        ],
        scratch_shapes=[pltpu.VMEM((P, N), F32)],
        interpret=interpret,
    )(xr, dta, dtr, Br, Cr)
    y = jnp.moveaxis(y.reshape(B, H, S, P), 1, 2)  # (B,S,H,P)
    return y, fs
