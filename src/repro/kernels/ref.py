"""Pure-jnp oracles for every kernel (the source of truth in tests)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..models.layers import _sdpa_dense
from ..models.ssd import ssd_chunked

F32 = jnp.float32


def flash_attention_ref(q, k, v, *, causal=True, window=0, softcap=0.0):
    """q (B,Sq,H,hd), k/v (B,Sk,K,hd) with implicit arange positions."""
    B, Sq = q.shape[:2]
    Sk = k.shape[1]
    qp = jnp.broadcast_to(jnp.arange(Sq, dtype=jnp.int32)[None], (B, Sq))
    kp = jnp.broadcast_to(jnp.arange(Sk, dtype=jnp.int32)[None], (B, Sk))
    return _sdpa_dense(
        q, k, v, qp, kp, window if window else None, causal, softcap or None
    )


def decode_attention_ref(q, k, v, pos_ids, lengths, *, window=0, softcap=0.0):
    """q (B,H,hd) single token; validity from pos_ids/lengths."""
    out = _sdpa_dense(
        q[:, None],  # (B,1,H,hd)
        k,
        v,
        lengths[:, None].astype(jnp.int32),
        pos_ids,
        window if window else None,
        True,
        softcap or None,
    )
    return out[:, 0]


def ssd_scan_ref(x, dt, A, B_, C_, *, chunk=128, h0=None):
    """Delegates to the model's chunked SSD (itself validated against the
    naive sequential recurrence in tests)."""
    return ssd_chunked(x, dt, A, B_, C_, chunk, h0=h0)


def ssd_sequential_ref(x, dt, A, B_, C_):
    """O(S) literal recurrence — the ground truth for ssd_chunked itself."""
    Bsz, S, H, P = x.shape
    N = B_.shape[-1]
    h = jnp.zeros((Bsz, H, P, N), F32)

    def step(h, inp):
        xt, dtt, Bt, Ct = inp  # (B,H,P), (B,H), (B,H,N), (B,H,N)
        dA = jnp.exp(dtt.astype(F32) * A.astype(F32))  # (B,H)
        h = h * dA[..., None, None] + dtt[..., None, None].astype(F32) * (
            xt[..., :, None].astype(F32) * Bt[..., None, :].astype(F32)
        )
        y = jnp.einsum("bhpn,bhn->bhp", h, Ct.astype(F32))
        return h, y

    xs = (
        jnp.moveaxis(x, 1, 0),
        jnp.moveaxis(dt, 1, 0),
        jnp.moveaxis(B_, 1, 0),
        jnp.moveaxis(C_, 1, 0),
    )
    h, ys = jax.lax.scan(step, h, xs)
    return jnp.moveaxis(ys, 0, 1).astype(x.dtype), h
