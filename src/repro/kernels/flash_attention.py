"""Flash attention for TPU (prefill/train path).

TPU-native adaptation (DESIGN.md §2): the score tile lives in VMEM and is
never written to HBM (the jnp lowering path streams ~S^2 bytes — measured
as the dominant memory term on qwen2 train_4k). Grid iterates (batch,
kv_head, q_block, k_block) with the k_block axis innermost — TPU grids
execute sequentially, so the (m, l, acc) streaming-softmax state lives in
VMEM scratch across k_block steps. GQA is handled by folding the G = H/K
query heads of a kv group into the q-block rows, keeping the MXU matmul
dims (G*bq, hd) x (hd, bk) hardware-aligned for bq=bk=128.

Supports: causal masking, sliding windows, logit softcap (gemma2),
arbitrary GQA ratios. Forward kernel; the backward pass rematerializes
through the jnp oracle via custom_vjp (a TPU bwd kernel is future work —
the fwd kernel is what serving needs).
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

F32 = jnp.float32
NEG_INF = -1e30


def _flash_kernel(
    q_ref,  # (1, 1, G, bq, hd)
    k_ref,  # (1, 1, bk, hd)
    v_ref,  # (1, 1, bk, hd)
    o_ref,  # (1, 1, G, bq, hd)
    m_scr,  # (G, bq) running max
    l_scr,  # (G, bq) running denominator
    acc_scr,  # (G, bq, hd) running numerator
    *,
    causal: bool,
    window: int,
    softcap: float,
    scale: float,
    block_q: int,
    block_k: int,
    num_k_blocks: int,
):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_pos = iq * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
    k_pos = ik * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
    mask = jnp.ones((block_q, block_k), jnp.bool_)
    if causal:
        mask &= q_pos >= k_pos
    if window > 0:
        mask &= (q_pos - k_pos) < window

    # skip fully-masked tiles (beyond the causal frontier / window)
    live = True
    if causal:
        live = (ik * block_k) <= (iq * block_q + block_q - 1)
    if window > 0:
        live = jnp.logical_and(
            live, (iq * block_q) - (ik * block_k + block_k - 1) < window
        )

    @pl.when(live)
    def _compute():
        q = q_ref[0, 0].astype(F32)  # (G, bq, hd)
        k = k_ref[0, 0].astype(F32)  # (bk, hd)
        v = v_ref[0, 0].astype(F32)  # (bk, hd)
        s = jax.lax.dot_general(
            q, k, (((2,), (1,)), ((), ())), preferred_element_type=F32
        )  # (G, bq, bk)
        s = s * scale
        if softcap > 0:
            s = softcap * jnp.tanh(s / softcap)
        s = jnp.where(mask[None], s, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        alpha = jnp.exp(m_prev - m_new)
        l_new = l_scr[...] * alpha + jnp.sum(p, axis=-1)
        pv = jax.lax.dot_general(
            p, v, (((2,), (0,)), ((), ())), preferred_element_type=F32
        )  # (G, bq, hd)
        acc_scr[...] = acc_scr[...] * alpha[..., None] + pv
        m_scr[...] = m_new
        l_scr[...] = l_new

    @pl.when(ik == num_k_blocks - 1)
    def _finalize():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / l[..., None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "softcap", "block_q", "block_k", "interpret"),
)
def flash_attention(
    q: jax.Array,  # (B, Sq, H, hd)
    k: jax.Array,  # (B, Sk, K, hd)
    v: jax.Array,  # (B, Sk, K, hd)
    *,
    causal: bool = True,
    window: int = 0,  # 0 = no window
    softcap: float = 0.0,  # 0 = no cap
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> jax.Array:
    B, Sq, H, hd = q.shape
    Sk, K = k.shape[1], k.shape[2]
    G = H // K
    assert H % K == 0 and Sq % block_q == 0 and Sk % block_k == 0, (
        q.shape, k.shape, block_q, block_k,
    )
    nq, nk = Sq // block_q, Sk // block_k
    scale = 1.0 / math.sqrt(hd)

    # (B, K, G, Sq, hd) so one program owns one kv-group's q rows
    qr = jnp.moveaxis(q.reshape(B, Sq, K, G, hd), 1, 3)
    kr = jnp.moveaxis(k, 1, 2)  # (B, K, Sk, hd)
    vr = jnp.moveaxis(v, 1, 2)

    kernel = functools.partial(
        _flash_kernel,
        causal=causal,
        window=window,
        softcap=softcap,
        scale=scale,
        block_q=block_q,
        block_k=block_k,
        num_k_blocks=nk,
    )
    out = pl.pallas_call(
        kernel,
        grid=(B, K, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, G, block_q, hd), lambda b, h, i, j: (b, h, 0, i, 0)),
            pl.BlockSpec((1, 1, block_k, hd), lambda b, h, i, j: (b, h, j, 0)),
            pl.BlockSpec((1, 1, block_k, hd), lambda b, h, i, j: (b, h, j, 0)),
        ],
        out_specs=pl.BlockSpec(
            (1, 1, G, block_q, hd), lambda b, h, i, j: (b, h, 0, i, 0)
        ),
        out_shape=jax.ShapeDtypeStruct((B, K, G, Sq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G, block_q), F32),
            pltpu.VMEM((G, block_q), F32),
            pltpu.VMEM((G, block_q, hd), F32),
        ],
        interpret=interpret,
    )(qr, kr, vr)
    return jnp.moveaxis(out, 3, 1).reshape(B, Sq, H, hd)
