"""Flash-decode for TPU: one new token per sequence against a (possibly
ring-buffer) KV cache.

Grid = (batch, kv_head, k_block), k_block innermost with (m, l, acc)
streaming-softmax scratch — the same VMEM-resident pattern as
flash_attention but with Sq == 1 folded into the G query heads of each kv
group, and validity driven by the cache's pos_ids (slot -> absolute
position, -1 = empty) instead of a causal frontier, which makes it
correct for both linear and SWA ring caches.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

F32 = jnp.float32
NEG_INF = -1e30


def _decode_kernel(
    qpos_ref,  # (1, 1) current absolute position (= lengths)
    q_ref,  # (1, 1, G, hd)
    k_ref,  # (1, 1, bk, hd)
    v_ref,  # (1, 1, bk, hd)
    pid_ref,  # (1, bk) pos_ids of the slots
    o_ref,  # (1, 1, G, hd)
    m_scr,  # (G, 1)
    l_scr,  # (G, 1)
    acc_scr,  # (G, hd)
    *,
    window: int,
    softcap: float,
    scale: float,
    num_k_blocks: int,
):
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(F32)  # (G, hd)
    k = k_ref[0, 0].astype(F32)  # (bk, hd)
    v = v_ref[0, 0].astype(F32)  # (bk, hd)
    pid = pid_ref[0]  # (bk,) int32
    qpos = qpos_ref[0, 0]  # scalar int32

    valid = (pid >= 0) & (pid <= qpos)
    if window > 0:
        valid &= (qpos - pid) < window

    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=F32
    )  # (G, bk)
    s = s * scale
    if softcap > 0:
        s = softcap * jnp.tanh(s / softcap)
    s = jnp.where(valid[None, :], s, NEG_INF)

    m_prev = m_scr[..., 0]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))  # (G,)
    p = jnp.exp(s - m_new[:, None])  # (G, bk)
    alpha = jnp.exp(m_prev - m_new)
    l_scr[..., 0] = l_scr[..., 0] * alpha + jnp.sum(p, axis=-1)
    pv = jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=F32
    )  # (G, hd)
    acc_scr[...] = acc_scr[...] * alpha[:, None] + pv
    m_scr[..., 0] = m_new

    @pl.when(ik == num_k_blocks - 1)
    def _finalize():
        l = jnp.maximum(l_scr[..., 0], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("window", "softcap", "block_k", "interpret")
)
def decode_attention(
    q: jax.Array,  # (B, H, hd) the new token's queries
    k: jax.Array,  # (B, Smax, K, hd)
    v: jax.Array,  # (B, Smax, K, hd)
    pos_ids: jax.Array,  # (B, Smax) int32, -1 = empty slot
    lengths: jax.Array,  # (B,) int32 current position
    *,
    window: int = 0,
    softcap: float = 0.0,
    block_k: int = 128,
    interpret: bool = False,
) -> jax.Array:
    B, H, hd = q.shape
    Smax, K = k.shape[1], k.shape[2]
    G = H // K
    assert Smax % block_k == 0, (Smax, block_k)
    nk = Smax // block_k
    scale = 1.0 / math.sqrt(hd)

    qr = q.reshape(B, K, G, hd)
    kr = jnp.moveaxis(k, 1, 2)  # (B, K, Smax, hd)
    vr = jnp.moveaxis(v, 1, 2)
    qpos = lengths.reshape(B, 1).astype(jnp.int32)

    kernel = functools.partial(
        _decode_kernel,
        window=window,
        softcap=softcap,
        scale=scale,
        num_k_blocks=nk,
    )
    out = pl.pallas_call(
        kernel,
        grid=(B, K, nk),
        in_specs=[
            pl.BlockSpec((1, 1), lambda b, h, j: (b, 0)),
            pl.BlockSpec((1, 1, G, hd), lambda b, h, j: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, block_k, hd), lambda b, h, j: (b, h, j, 0)),
            pl.BlockSpec((1, 1, block_k, hd), lambda b, h, j: (b, h, j, 0)),
            pl.BlockSpec((1, block_k), lambda b, h, j: (b, j)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, hd), lambda b, h, j: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, K, G, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G, 1), F32),
            pltpu.VMEM((G, 1), F32),
            pltpu.VMEM((G, hd), F32),
        ],
        interpret=interpret,
    )(qpos, qr, kr, vr, pos_ids)
    return out.reshape(B, H, hd)
