"""Logical-axis sharding (t5x-style) with divisibility fallback.

Model code annotates tensors with *logical* axis names ("batch", "heads",
"ff", ...). A rules table maps logical names to mesh axes. A logical axis
whose dimension is not divisible by the mapped mesh-axis size silently
falls back to replication for that axis — this is what lets e.g.
gemma2-2b (8 heads) lower on a 16-way "model" axis without manual
special-casing, while granite (32 heads) gets full tensor parallelism.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Optional, Sequence, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

AxisName = Union[str, None]
Rules = dict[str, Union[str, tuple[str, ...], None]]

# ---------------------------------------------------------------------------
# Rule tables
# ---------------------------------------------------------------------------

#: Default rules for a ("data", "model") mesh; the "pod" axis (if present)
#: is prepended to the batch/fsdp mapping by `with_pod_axis`.
TRAIN_RULES: Rules = {
    "batch": "data",
    "seq": None,
    "embed": None,
    "fsdp": "data",          # FSDP shards a params dim over the data axis
    "heads": "model",
    "kv_heads": "model",
    # fallback TP axis: claims "model" only when heads/kv_heads could not
    # (e.g. gemma2's 8q/4kv heads or qwen2's 14q/2kv on a 16-way axis).
    # Safe because rope uses interleaved pairing (layers.apply_rope).
    "head_dim": "model",
    # ACTIVATION-only attention axes. Default None: forcing q/k/v activation
    # layouts was measured to fight GSPMD's partial kv-head sharding and
    # trigger "involuntary full rematerialization" (full-batch K/V
    # all-gathers, 2x4GiB/layer on mixtral train) — see EXPERIMENTS.md
    # SPerf. Params keep their own (heads/head_dim) mappings above.
    "act_heads": "model",
    "act_kv_heads": None,
    "act_head_dim": None,
    # PARAM fallbacks: q weights may claim "model" on head_dim when heads
    # cannot (gemma2/qwen2). KV weights must NOT (measured: hd-sharded K
    # conflicts with GSPMD's partial kv-head sharding of the GQA reshape
    # and replicates K/V over the full batch). The KV *cache* still
    # hd-shards via "head_dim" (cache_axes) — that is where gemma2's
    # decode 54.8->4.1 GiB win came from.
    "q_param_hd": "model",
    "kv_param_hd": None,
    "qkv": "model",          # fused q/k/v head-ish output dims
    "ff": "model",
    "vocab": "model",
    "experts": "model",      # expert parallelism
    "expert_group": None,
    "moe_ff": "model",       # MoE hidden dim (TP-MoE when EP impossible)
    "capacity": None,        # alt: shard expert capacity rows (moe_cshard)
    "ssm_heads": "model",
    "ssm_inner": "model",
    "ssm_state": None,
    "conv_ch": "model",
    "kv_seq": None,
}

SERVE_RULES: Rules = dict(
    TRAIN_RULES,
    fsdp=None,               # serving keeps whole (bf16) weights per TP group
    batch="data",
)

#: long-context decode: batch=1 ⇒ the data axis is idle for activations,
#: so shard the KV/state sequence dim over it AND ZeRO-style shard the
#: bf16 weights over it too (they are streamed anyway at batch=1).
LONG_RULES: Rules = dict(
    SERVE_RULES,
    batch=None,
    kv_seq="data",
    fsdp="data",
)


def with_pod_axis(rules: Rules) -> Rules:
    """Extend a single-pod rules table to the ("pod","data","model") mesh."""
    r = dict(rules)
    for k, v in r.items():
        if v == "data" and k in ("batch",):
            r[k] = ("pod", "data")
    return r


def rules_for(shape_kind: str, *, multi_pod: bool) -> Rules:
    base = {
        "train": TRAIN_RULES,
        "prefill": SERVE_RULES,
        "decode": SERVE_RULES,
        "long": LONG_RULES,
    }[shape_kind]
    return with_pod_axis(base) if multi_pod else base


# ---------------------------------------------------------------------------
# Context: the active (mesh, rules) pair used by model-internal constraints
# ---------------------------------------------------------------------------

class _ShardingCtx(threading.local):
    def __init__(self):
        self.mesh: Optional[Mesh] = None
        self.rules: Optional[Rules] = None


_CTX = _ShardingCtx()


@contextlib.contextmanager
def sharding_ctx(mesh: Optional[Mesh], rules: Optional[Rules]):
    prev = (_CTX.mesh, _CTX.rules)
    _CTX.mesh, _CTX.rules = mesh, rules
    try:
        yield
    finally:
        _CTX.mesh, _CTX.rules = prev


def current_mesh() -> Optional[Mesh]:
    return _CTX.mesh


# ---------------------------------------------------------------------------
# Spec construction with divisibility fallback
# ---------------------------------------------------------------------------

def _axis_size(mesh: Mesh, axis: Union[str, tuple[str, ...]]) -> int:
    if isinstance(axis, tuple):
        n = 1
        for a in axis:
            n *= mesh.shape[a]
        return n
    return mesh.shape[axis]


def spec_for(
    shape: Sequence[int],
    logical_axes: Sequence[Optional[str]],
    rules: Rules,
    mesh: Mesh,
) -> P:
    """Map logical axes to a PartitionSpec, dropping non-divisible axes.

    A mesh axis may appear at most once in a PartitionSpec; when two
    logical dims map to the same mesh axis the earlier dim wins.
    """
    assert len(shape) == len(logical_axes), (shape, logical_axes)
    used: set[str] = set()
    out: list[Union[str, tuple[str, ...], None]] = []
    for dim, name in zip(shape, logical_axes):
        mesh_axis = rules.get(name) if name else None
        if mesh_axis is None:
            out.append(None)
            continue
        axes = mesh_axis if isinstance(mesh_axis, tuple) else (mesh_axis,)
        kept = tuple(a for a in axes if a not in used)
        if not kept:
            out.append(None)
            continue
        if dim % _axis_size(mesh, kept) != 0:
            # partial fallback: try the largest divisible prefix
            while kept and dim % _axis_size(mesh, kept) != 0:
                kept = kept[:-1]
            if not kept:
                out.append(None)
                continue
        used.update(kept)
        out.append(kept if len(kept) > 1 else kept[0])
    return P(*out)


def shard(x: jax.Array, *logical_axes: Optional[str]) -> jax.Array:
    """Constrain an activation to the current (mesh, rules) context.

    No-op outside a sharding context (e.g. single-device smoke tests).
    """
    mesh, rules = _CTX.mesh, _CTX.rules
    if mesh is None or rules is None:
        return x
    spec = spec_for(x.shape, logical_axes, rules, mesh)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def tree_shardings(axes_tree, shapes_tree, rules: Rules, mesh: Mesh):
    """NamedShardings for a params pytree given its logical-axes pytree."""

    def one(axes, shaped):
        return NamedSharding(mesh, spec_for(shaped.shape, axes, rules, mesh))

    return jax.tree.map(
        one, axes_tree, shapes_tree, is_leaf=lambda a: isinstance(a, tuple)
    )


def tree_specs(axes_tree, shapes_tree, rules: Rules, mesh: Mesh):
    def one(axes, shaped):
        return spec_for(shaped.shape, axes, rules, mesh)

    return jax.tree.map(
        one, axes_tree, shapes_tree, is_leaf=lambda a: isinstance(a, tuple)
    )
