"""Int8 error-feedback gradient compression for data-parallel reduction.

The compressed reduction transmits int8 shards (visible as ``s8``
all-gathers in the compiled HLO — the dry-run collective parser verifies
the 4x wire reduction vs f32), dequantizes locally, and keeps the
quantization residual as per-worker error feedback so the scheme is
unbiased over time (Seide et al. / EF-SGD).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

F32 = jnp.float32


def quantize_int8(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Per-tensor symmetric int8. Returns (q, scale)."""
    amax = jnp.max(jnp.abs(x))
    scale = jnp.maximum(amax / 127.0, 1e-12)
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(F32)


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(F32) * scale


def ef_compress(x: jax.Array, err: jax.Array):
    """Error-feedback compression of one tensor.

    Returns (q, scale, new_err) with x + err = deq(q, scale) + new_err.
    """
    target = x.astype(F32) + err
    q, scale = quantize_int8(target)
    new_err = target - dequantize_int8(q, scale)
    return q, scale, new_err


def ef_allreduce_mean(x: jax.Array, err: jax.Array, axis_name: str):
    """Mean-reduce `x` across `axis_name` inside shard_map, transmitting
    int8: all-gather(q: s8) + all-gather(scale: f32 scalar), then local
    dequant-sum. Returns (mean, new_err)."""
    q, scale, new_err = ef_compress(x, err)
    qs = jax.lax.all_gather(q, axis_name)  # s8 on the wire
    ss = jax.lax.all_gather(scale, axis_name)
    n = qs.shape[0]
    deq = qs.astype(F32) * ss.reshape((n,) + (1,) * x.ndim)
    return jnp.sum(deq, axis=0) / n, new_err


def tree_ef_allreduce_mean(grads, errs, axis_name: str):
    """Apply ef_allreduce_mean leaf-wise over a gradient pytree."""
    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = treedef.flatten_up_to(errs)
    out_g, out_e = [], []
    for g, e in zip(flat_g, flat_e):
        m, ne = ef_allreduce_mean(g, e, axis_name)
        out_g.append(m.astype(g.dtype))
        out_e.append(ne)
    return treedef.unflatten(out_g), treedef.unflatten(out_e)


def init_error_tree(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, F32), params)
