from .sharding import (
    LONG_RULES,
    SERVE_RULES,
    TRAIN_RULES,
    rules_for,
    shard,
    sharding_ctx,
    spec_for,
    tree_shardings,
    tree_specs,
    with_pod_axis,
)
